"""Continuous-batching scheduler: round-chunked decode equivalence with
the one-shot engine (dense and block-paged caches), lane
admission/eviction over a backlog, bucket selection, and vote-aware
early stopping as real (not accounted) token savings."""

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import routing as routing_lib
from repro.core import voting
from repro.core.confidence import Vote
from repro.data.pipeline import encode_prompts
from repro.data.tokenizer import default_tokenizer
from repro.serving.batch import (GenConfig, first_eos_lengths,
                                 harvest_lengths, make_buckets, pick_bucket)
from repro.serving.engine import generate
from repro.serving.scheduler import (Request, RequestGroup, Scheduler,
                                     StopPolicy)

MAXP = 64


@pytest.fixture(scope="module")
def setup():
    from repro.models import model as M
    tok = default_tokenizer()
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128, vocab_size=tok.vocab_size, remat=False,
                      source="test")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg, tok


# ----------------------------------------------------------------------
# Bucketing
# ----------------------------------------------------------------------

def test_make_buckets_ladder():
    assert make_buckets(160) == (32, 64, 128, 160)
    assert make_buckets(64) == (32, 64)
    assert make_buckets(8, 1) == (1, 2, 4, 8)


def test_pick_bucket_expected():
    buckets = make_buckets(160)
    assert pick_bucket(1, buckets) == 32
    assert pick_bucket(32, buckets) == 32
    assert pick_bucket(33, buckets) == 64
    assert pick_bucket(100, buckets) == 128
    assert pick_bucket(150, buckets) == 160
    # longer than every bucket: callers truncate to the largest
    assert pick_bucket(999, buckets) == 160


# ----------------------------------------------------------------------
# Round harvest edge cases
# ----------------------------------------------------------------------

EOS = 99


def test_harvest_eos_at_position_zero():
    """A lane whose very first round token is EOS harvests exactly that
    one token."""
    toks = np.array([[EOS, 5, 5, 5], [5, EOS, 5, 5]], np.int32)
    lengths, found = harvest_lengths(toks, np.array([4, 4], np.int32), EOS)
    assert lengths.tolist() == [1, 2]
    assert found.tolist() == [True, True]


def test_harvest_zero_remaining_budget():
    """A zero (or stale negative) remaining budget harvests nothing —
    even when the round emitted an EOS past the budget window — and
    never produces a negative slice length."""
    toks = np.array([[EOS, 5, 5, 5], [5, 5, 5, 5]], np.int32)
    lengths, found = harvest_lengths(toks, np.array([0, -3], np.int32), EOS)
    assert lengths.tolist() == [0, 0]
    assert found.tolist() == [False, False]


def test_harvest_eos_beyond_limit_ignored():
    toks = np.array([[5, 5, EOS, 5]], np.int32)
    lengths, found = harvest_lengths(toks, np.array([2], np.int32), EOS)
    assert lengths.tolist() == [2] and found.tolist() == [False]
    # limits above the round width clamp to the width
    lengths, found = harvest_lengths(toks, np.array([99], np.int32), EOS)
    assert lengths.tolist() == [3] and found.tolist() == [True]


def test_harvest_all_dead_wave():
    """No live rows (and even a zero-width round) must not trip the
    vectorized harvest."""
    lengths, found = harvest_lengths(np.zeros((0, 4), np.int32),
                                     np.zeros((0,), np.int32), EOS)
    assert lengths.shape == (0,) and found.shape == (0,)
    lengths, found = harvest_lengths(np.zeros((3, 0), np.int32),
                                     np.zeros((3,), np.int32), EOS)
    assert lengths.tolist() == [0, 0, 0]
    assert found.tolist() == [False, False, False]


def test_first_eos_lengths_edges():
    toks = np.array([[EOS, 1, 2], [1, 2, 3], [1, EOS, EOS]], np.int32)
    assert first_eos_lengths(toks, EOS).tolist() == [1, 3, 2]
    assert first_eos_lengths(np.zeros((2, 0), np.int32), EOS).tolist() == [0, 0]


# ----------------------------------------------------------------------
# Equivalence: round-chunked decode == one-shot engine
# ----------------------------------------------------------------------

def test_round_decode_bitmatches_engine(setup):
    """With the same lane pool, padding and master key, chunking the
    decode into R-token rounds must not change a single sampled token."""
    params, cfg, tok = setup
    prompts = ["Q: Compute 1 + 1.\nA: ", "Q: hi\nA: ",
               "Q: what is 9 * 9?\nA: ", "Q: x\nA: "]
    gcfg = GenConfig(max_new_tokens=24, temperature=0.7)
    toks, lens = encode_prompts(prompts, tok, MAXP)
    key = jax.random.PRNGKey(7)
    eng_toks, eng_lens = generate(params, cfg, toks, lens, key, gcfg)

    sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=6,
                      max_prompt_len=MAXP, buckets=(MAXP,), admit_buckets=(4,))
    comps, stats = sched.run([Request(uid=i, prompt=p)
                              for i, p in enumerate(prompts)], key)
    for i, c in enumerate(comps):
        assert c.gen_len == eng_lens[i]
        assert np.array_equal(c.tokens, eng_toks[i][: eng_lens[i]])
    assert stats.rounds == 4            # ceil(24 / 6)
    assert stats.cancelled == 0


# ----------------------------------------------------------------------
# Equivalence: block-paged cache == dense cache == one-shot engine
# ----------------------------------------------------------------------

@pytest.mark.parametrize("block_size", [8, 16])
def test_paged_bitmatches_engine_greedy(setup, block_size):
    """Greedy decoding through the block-paged cache must reproduce the
    dense one-shot engine token-for-token (the paged cache is a layout
    change, not a numerics change)."""
    params, cfg, tok = setup
    prompts = ["Q: Compute 1 + 1.\nA: ", "Q: hi\nA: ",
               "Q: what is 9 * 9?\nA: ", "Q: x\nA: "]
    gcfg = GenConfig(max_new_tokens=24, temperature=0.0)
    toks, lens = encode_prompts(prompts, tok, MAXP)
    key = jax.random.PRNGKey(7)
    eng_toks, eng_lens = generate(params, cfg, toks, lens, key, gcfg)

    sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=6,
                      max_prompt_len=MAXP, buckets=(MAXP,), admit_buckets=(4,),
                      paged=True, block_size=block_size)
    comps, stats = sched.run([Request(uid=i, prompt=p)
                              for i, p in enumerate(prompts)], key)
    for i, c in enumerate(comps):
        assert c.gen_len == eng_lens[i]
        assert np.array_equal(c.tokens, eng_toks[i][: eng_lens[i]])
    # the paged pool held strictly less than the dense cache would
    assert 0 < stats.peak_cache_bytes < stats.dense_cache_bytes
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0


def test_paged_bitmatches_dense_scheduler_sampled(setup):
    """Sampled decoding: the paged scheduler draws exactly the tokens
    the dense scheduler draws (same master key, lane pool, padding) —
    the gathered page view is laid out slot-for-slot like the dense
    cache, so even the softmax sums are bit-identical."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=20, temperature=0.7)
    reqs = [Request(uid=i, prompt=f"Q: item {i} says hello\nA: ")
            for i in range(10)]
    key = jax.random.PRNGKey(3)
    runs = {}
    for paged in (False, True):
        sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=5,
                          max_prompt_len=MAXP, paged=paged, block_size=8)
        runs[paged], _ = sched.run(reqs, key)
    for cd, cp in zip(runs[False], runs[True]):
        assert cd.gen_len == cp.gen_len
        assert np.array_equal(cd.tokens, cp.tokens)


def test_paged_budget_crossing_mid_round_matches_dense(setup):
    """Budgets that end mid-round make lanes keep stepping past their
    budget inside the jitted round: those writes must spill into the
    trash block / the lane's own unread slots without corrupting other
    lanes (paged tokens still match dense exactly)."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=32, temperature=0.7, eos_id=-1)
    # budget 10 with round_tokens 4: third round crosses the budget
    reqs = [Request(uid=i, prompt=f"Q: item {i}\nA: ", max_new_tokens=10)
            for i in range(8)]
    key = jax.random.PRNGKey(11)
    runs = {}
    for paged in (False, True):
        sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=4,
                          max_prompt_len=MAXP, paged=paged, block_size=8)
        runs[paged], stats = sched.run(reqs, key)
        if paged:
            assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    for cd, cp in zip(runs[False], runs[True]):
        assert cd.gen_len == cp.gen_len == 10
        assert np.array_equal(cd.tokens, cp.tokens)


# ----------------------------------------------------------------------
# Equivalence: shared-prefix grouped == dense, with one prefill/question
# ----------------------------------------------------------------------

def _vote_groups(n_questions, k, max_new=None):
    return [RequestGroup([
        Request(uid=qi * k + j, prompt=f"Q: item {qi} says hello\nA: ",
                group=qi, max_new_tokens=max_new) for j in range(k)])
        for qi in range(n_questions)]


def test_grouped_shared_bitmatches_engine_greedy(setup, monkeypatch):
    """A K-vote group prefilled once and fanned out through shared
    blocks must reproduce the dense one-shot engine token-for-token —
    and must do so through prefill_shared alone (the per-lane prefill
    path is poisoned)."""
    params, cfg, tok = setup
    from repro.serving import scheduler as sched_mod
    k = 4
    prompt = "Q: what is 9 * 9?\nA: "
    gcfg = GenConfig(max_new_tokens=24, temperature=0.0)
    toks, lens = encode_prompts([prompt] * k, tok, MAXP)
    eng_toks, eng_lens = generate(params, cfg, toks, lens,
                                  jax.random.PRNGKey(7), gcfg)

    calls = {"shared": 0}
    orig = sched_mod.prefill_shared

    def counting(params_, cfg_, prompts_, lengths_, max_len_):
        calls["shared"] += 1
        return orig(params_, cfg_, prompts_, lengths_, max_len_)

    def poisoned(*a, **kw):
        raise AssertionError("per-lane prefill used under share_prefix")

    monkeypatch.setattr(sched_mod, "prefill_shared", counting)
    monkeypatch.setattr(sched_mod, "prefill_jit", poisoned)
    sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=6,
                      max_prompt_len=MAXP, buckets=(MAXP,),
                      admit_buckets=(4,), paged=True, block_size=8,
                      share_prefix=True)
    grp = RequestGroup([Request(uid=j, prompt=prompt, group=0)
                        for j in range(k)])
    comps, stats = sched.run([grp], jax.random.PRNGKey(7))
    assert calls["shared"] == 1                 # one jitted prefill call
    assert stats.prefill_prompts == 1           # covering one prompt row
    assert stats.prefill_tokens == len(tok.encode(prompt, bos=True))
    assert stats.shared_lanes == k - 1
    for i, c in enumerate(comps):
        assert c.gen_len == eng_lens[i]
        assert np.array_equal(c.tokens, eng_toks[i][: eng_lens[i]])
    assert sched.pool.in_use == 0 and sched.pool.reserved == 0


@pytest.mark.parametrize("block_size", [8, 16])
def test_grouped_shared_bitmatches_dense_scheduler_sampled(setup,
                                                           block_size):
    """Sampled decoding: grouped shared-prefix serving draws exactly the
    tokens the dense scheduler draws over a multi-wave backlog (same
    master key, lane pool, padding), while prefilling each question
    once instead of K times."""
    params, cfg, tok = setup
    # eos_id=-1 pins every lane's lifetime to its budget: group-atomic
    # admission then composes the same waves as the dense scheduler's
    # lane-at-a-time backfill, which bit-equality requires (admission
    # step feeds the sampling fold_in — see the batch.py PRNG contract)
    gcfg = GenConfig(max_new_tokens=20, temperature=0.7, eos_id=-1)
    groups = _vote_groups(5, 4)
    key = jax.random.PRNGKey(3)
    runs, stats = {}, {}
    for mode in ("dense", "shared"):
        sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=5,
                          max_prompt_len=MAXP, paged=mode == "shared",
                          block_size=block_size,
                          share_prefix=mode == "shared")
        runs[mode], stats[mode] = sched.run(groups, key)
    assert stats["shared"].prefill_prompts == 5         # 1 per question
    assert stats["dense"].prefill_prompts == 20         # K per question
    assert stats["shared"].prefill_tokens * 4 == stats["dense"].prefill_tokens
    for cd, cp in zip(runs["dense"], runs["shared"]):
        assert cd.gen_len == cp.gen_len
        assert np.array_equal(cd.tokens, cp.tokens)


def test_grouped_budget_crossing_mid_round_matches_dense(setup):
    """Group lanes stepping past their budget inside a jitted round must
    spill into the trash block / their own private tails without
    corrupting the shared prompt blocks other lanes still read."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=32, temperature=0.7, eos_id=-1)
    groups = _vote_groups(3, 4, max_new=10)   # budget ends mid-round
    key = jax.random.PRNGKey(11)
    runs = {}
    for mode in ("dense", "shared"):
        sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=4,
                          max_prompt_len=MAXP, paged=mode == "shared",
                          block_size=8, share_prefix=mode == "shared")
        runs[mode], _ = sched.run(groups, key)
        if mode == "shared":
            assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    for cd, cp in zip(runs["dense"], runs["shared"]):
        assert cd.gen_len == cp.gen_len == 10
        assert np.array_equal(cd.tokens, cp.tokens)


def test_grouped_nonidentical_prompts_fall_back(setup):
    """RCV-style groups (per-lane confidence headers -> different
    prompts) must not share — and must still match the dense scheduler
    exactly."""
    params, cfg, tok = setup
    # eos_id=-1: uniform lane lifetimes keep the two schedulers' waves
    # aligned (see test_grouped_shared_bitmatches_dense_scheduler_sampled)
    gcfg = GenConfig(max_new_tokens=16, temperature=0.7, eos_id=-1)
    k = 3
    groups = [RequestGroup([
        Request(uid=qi * k + j, prompt=f"[conf {j}] Q: item {qi}\nA: ",
                group=qi) for j in range(k)]) for qi in range(3)]
    key = jax.random.PRNGKey(5)
    runs, stats = {}, {}
    for mode in ("dense", "shared"):
        sched = Scheduler(params, cfg, tok, gcfg, n_lanes=3, round_tokens=4,
                          max_prompt_len=MAXP, paged=mode == "shared",
                          block_size=8, share_prefix=mode == "shared")
        runs[mode], stats[mode] = sched.run(groups, key)
    assert stats["shared"].shared_lanes == 0      # nothing was shareable
    assert stats["shared"].prefill_prompts == 9   # every lane prefilled
    for cd, cp in zip(runs["dense"], runs["shared"]):
        assert cd.gen_len == cp.gen_len
        assert np.array_equal(cd.tokens, cp.tokens)


def test_cross_request_prefix_cache_reuses_blocks(setup):
    """Requests sharing a long instruction header reuse its full blocks
    through the scheduler's prefix cache (HBM dedup) without changing a
    single sampled token vs the dense scheduler."""
    params, cfg, tok = setup
    gcfg = GenConfig(max_new_tokens=12, temperature=0.7)
    header = "You must answer carefully and briefly. "   # > several blocks
    reqs = [Request(uid=i, prompt=f"{header}Q: item {i}\nA: ")
            for i in range(6)]
    key = jax.random.PRNGKey(13)
    runs, stats = {}, {}
    for mode in ("dense", "shared"):
        sched = Scheduler(params, cfg, tok, gcfg, n_lanes=2, round_tokens=4,
                          max_prompt_len=MAXP, paged=mode == "shared",
                          block_size=8, share_prefix=mode == "shared")
        runs[mode], stats[mode] = sched.run(reqs, key)
        if mode == "shared":
            assert sched.pool.in_use == 0 and sched.pool.reserved == 0
    assert stats["shared"].prefix_hits > 0
    assert stats["shared"].prefix_hit_blocks > 0
    for cd, cp in zip(runs["dense"], runs["shared"]):
        assert cd.gen_len == cp.gen_len
        assert np.array_equal(cd.tokens, cp.tokens)


# ----------------------------------------------------------------------
# Continuous batching over a backlog
# ----------------------------------------------------------------------

def _no_eos(max_new):
    # eos_id outside the vocab: every lane runs exactly to budget
    return GenConfig(max_new_tokens=max_new, temperature=0.7, eos_id=-1)


def test_backlog_streams_through_lane_pool(setup):
    params, cfg, tok = setup
    sched = Scheduler(params, cfg, tok, _no_eos(8), n_lanes=4,
                      round_tokens=4, max_prompt_len=MAXP)
    reqs = [Request(uid=i, prompt=f"Q: item {i}\nA: ") for i in range(10)]
    comps, stats = sched.run(reqs, jax.random.PRNGKey(1))
    assert [c.uid for c in comps] == list(range(10))
    assert all(c.gen_len == 8 and not c.cancelled for c in comps)
    # 10 requests x 2 rounds each over 4 lanes: at least 3 admission waves
    assert stats.prefill_prompts == 10
    assert stats.prefills >= 3
    assert stats.generated_tokens == 80


# ----------------------------------------------------------------------
# Early stop: killed lanes really decode fewer tokens
# ----------------------------------------------------------------------

class _FirstFinishKills(StopPolicy):
    def observe(self, comp):
        return (comp.group,)


def test_early_stopped_lanes_generate_strictly_fewer(setup):
    params, cfg, tok = setup
    gcfg = _no_eos(32)
    sched = Scheduler(params, cfg, tok, gcfg, n_lanes=4, round_tokens=4,
                      max_prompt_len=MAXP)
    # lane 0 of each group exhausts its budget after round 1; the policy
    # then kills the group's other lanes mid-flight
    reqs = [Request(uid=i, prompt=f"Q: item {i}\nA: ", group=i // 5,
                    max_new_tokens=(4 if i % 5 == 0 else 32))
            for i in range(10)]
    es, es_stats = sched.run(reqs, jax.random.PRNGKey(1),
                             stop_policy=_FirstFinishKills())
    full, full_stats = sched.run(reqs, jax.random.PRNGKey(1))

    assert not es[0].cancelled and es[0].gen_len == 4
    for c_es, c_full in zip(es[1:5], full[1:5]):
        assert c_es.cancelled
        assert c_es.gen_len < c_full.gen_len        # strictly fewer
    assert es_stats.generated_tokens < full_stats.generated_tokens
    assert es_stats.cancelled == 8
    # the never-admitted request of each killed group costs zero tokens
    assert es[4].gen_len == 0 and es[4].cancelled


# ----------------------------------------------------------------------
# VoteEarlyStop == decide_with_early_stop (decision equivalence)
# ----------------------------------------------------------------------

def _fake_completion(group, vote: Vote, uid=0):
    from repro.serving.scheduler import Completion
    return Completion(uid=uid, group=group, tokens=np.zeros((0,), np.int32),
                      gen_len=vote.gen_tokens, text="", cancelled=False,
                      meta={"vote": vote})


@pytest.mark.parametrize("tau", [0.1, 0.5, 0.6, 0.9, 1.0])
def test_vote_early_stop_matches_offline_simulation(tau):
    """Feeding completions in gen-length order must reproduce the
    accept/route decision of voting.decide_with_early_stop."""
    rng = np.random.RandomState(int(tau * 10))
    for trial in range(30):
        k = rng.randint(1, 9)
        votes = [Vote(answer=rng.choice(["a", "b", None]),
                      confidence=float(rng.choice([0.3, 0.7, 1.0])),
                      gen_tokens=int(rng.randint(1, 60)))
                 for _ in range(k)]
        policy = routing_lib.VoteEarlyStop(
            tau, {0: [v.confidence for v in votes]},
            parse=lambda c: c.meta["vote"])
        order = sorted(range(k), key=lambda i: votes[i].gen_tokens)
        for i in order:
            if policy.observe(_fake_completion(0, votes[i], uid=i)):
                break              # group killed: later lanes never finish
        ref = voting.decide_with_early_stop(votes, tau)
        assert 0 in policy.decisions
        dec = policy.decisions[0]
        assert dec.accepted == ref.accepted
        assert dec.answer == ref.answer
        assert dec.decision_tokens <= ref.decision_tokens + 0


# ----------------------------------------------------------------------
# Streamed sampling through routing
# ----------------------------------------------------------------------

def test_sample_k_streamed_saves_tokens_vs_full(setup):
    params, cfg, tok = setup
    slm = routing_lib.SLM(params, cfg, tok,
                          GenConfig(max_new_tokens=24, temperature=0.7),
                          max_prompt_len=MAXP, lane_budget=16,
                          round_tokens=4)
    import repro.data.tasks as tasks_lib
    items = tasks_lib.make_benchmark("arith", 4, seed=1)
    levels = [1.0] * 4
    key = jax.random.PRNGKey(9)
    es, es_stats = routing_lib.sample_k_streamed(slm, items, levels, key,
                                                 tau=1.0, early_stop=True)
    full, full_stats = routing_lib.sample_k_streamed(slm, items, levels, key,
                                                     tau=1.0, early_stop=False)
    assert es_stats.generated_tokens <= full_stats.generated_tokens
    for r in es:
        assert r.generated_tokens <= sum(v.gen_tokens for v in r.votes) + 1
        assert r.decision.used_tokens == r.generated_tokens
