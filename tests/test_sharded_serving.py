"""Unit tests for the sharded-serving building blocks.

The end-to-end contract (randomized traces on a simulated 4-device mesh
bit-matching the single-device oracle) lives in test_serving_trace.py's
sharded mode; this module covers the pieces in isolation:

  * ``distributed/sharding.py`` divisibility fallbacks — mamba2's 50280
    vocab is not divisible by model=16 so the embedding falls back to
    sharding d_model, and the FSDP expert-weight rule crosses its
    parameter threshold — checked on an AbstractMesh, proving the rules
    never touch device state;
  * ``serving_cache_specs``, the lane/block-axis spec dict the sharded
    decode rounds run under;
  * per-shard ``BlockPool`` id namespaces (``id_base``): disjoint global
    ids, per-pool trash rows, and the global->local table arithmetic the
    dispatch path uses;
  * ``launch/mesh.py`` sim-device helpers (the conftest gives the whole
    test process 8 simulated CPU devices);
  * ``Scheduler(mesh=...)`` validation plus device *pinning*: a 1-device
    mesh is a legal "shard count 1" that routes decode through shard_map
    onto exactly that device — the unit of cascade tier placement;
  * model-axis tensor parallelism via plain GSPMD (device_put to the
    param specs): greedy tokens equal, which is the documented contract
    for model>1 (shard_map data-parallel is the bit-exact path; the
    model axis is allclose-level and therefore lives OUTSIDE the
    serving loop's mesh, which rejects model>1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import (ensure_sim_devices, make_sim_mesh,
                               make_tier_mesh)
from repro.serving.batch import GenConfig
from repro.serving.block_pool import BlockPool
from repro.serving.scheduler import Request, Scheduler

POD_ABSTRACT = AbstractMesh((("data", 16), ("model", 16)))


# ----------------------------------------------------------------------
# param_spec divisibility fallbacks (AbstractMesh: no device state)
# ----------------------------------------------------------------------

def _abstract_params(cfg):
    from repro.models import model as M
    return jax.eval_shape(lambda k: M.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def test_mamba2_vocab_falls_back_to_dmodel_sharding():
    """50280 % 16 != 0: the embedding cannot shard its vocab dim over
    model=16, so the rule falls back to d_model (2048, divisible)."""
    cfg = get_config("mamba2-1.3b")
    assert cfg.vocab_size % 16 != 0 and cfg.d_model % 16 == 0
    specs = sh.param_specs(cfg, _abstract_params(cfg), POD_ABSTRACT)
    assert specs["embed"]["embedding"] == P(None, "model")


def test_embedding_replicates_when_nothing_divides():
    """Neither dim divisible -> fully replicated, never a crash."""
    cfg = get_config("mamba2-1.3b")
    leaf = jax.ShapeDtypeStruct((50280, 2049), jnp.float32)
    spec = sh.param_spec(cfg, (jax.tree_util.DictKey("embedding"),),
                         leaf, POD_ABSTRACT)
    assert spec == P(None, None)


def test_fsdp_threshold_crossover():
    """The same 4-d MoE expert leaf is data-sharded on dim 2 only when
    the config's parameter count crosses FSDP_PARAM_THRESHOLD."""
    path = (jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("wi_gate"))
    leaf = jax.ShapeDtypeStruct((4, 16, 5120, 8192), jnp.float32)
    big = get_config("llama4-scout-17b-a16e")
    small = get_config("olmoe-1b-7b")
    assert big.param_count() > sh.FSDP_PARAM_THRESHOLD
    assert small.param_count() < sh.FSDP_PARAM_THRESHOLD
    assert sh.param_spec(big, path, leaf, POD_ABSTRACT) == \
        P(None, "model", "data", None)
    assert sh.param_spec(small, path, leaf, POD_ABSTRACT) == \
        P(None, "model", None, None)


def test_serving_cache_specs_layout():
    """Lane axis on pos/cache_pos/block_tables, block axis on the
    layer-stacked leaves, the shared position ruler replicated."""
    spec = sh.serving_cache_specs(
        {"pos": 0, "kpos": 0, "cache_pos": 0, "block_tables": 0,
         "k": 0, "v": 0, "k_scale": 0, "conv": 0, "ssm": 0})
    assert spec["pos"] == P("data")
    assert spec["kpos"] == P()
    assert spec["cache_pos"] == P("data", None)
    assert spec["block_tables"] == P("data", None)
    for name in ("k", "v", "k_scale", "conv", "ssm"):
        assert spec[name] == P(None, "data")


# ----------------------------------------------------------------------
# Per-shard BlockPool id namespaces
# ----------------------------------------------------------------------

def test_block_pool_id_base_namespaces_disjoint():
    """Shard s's pool owns global ids s*(n+1)+1 .. s*(n+1)+n; id 0 of
    each slab is that shard's trash row.  Allocations from different
    pools can never collide, and each pool rejects foreign ids."""
    n = 6
    pools = [BlockPool(n, 8, id_base=s * (n + 1)) for s in range(3)]
    for p in pools:
        assert p.reserve(n)
    got = [set(p.alloc(n)) for p in pools]
    assert got[0] == set(range(1, n + 1))
    assert got[1] == set(range(n + 2, 2 * n + 2))
    assert not (got[0] & got[1]) and not (got[1] & got[2])
    # global -> local arithmetic used by the dispatch path
    for s, ids in enumerate(got):
        local = {g - s * (n + 1) for g in ids}
        assert local == set(range(1, n + 1))
    with pytest.raises(ValueError, match="not an allocatable block id"):
        pools[0].free([n + 2])          # shard 1's id in shard 0's pool
    for p, ids in zip(pools, got):
        p.free(sorted(ids))
        assert p.leak_report() is None


def test_block_pool_zero_base_unchanged():
    """id_base=0 is exactly the historical single-pool layout."""
    p = BlockPool(4, 8)
    assert p.reserve(4)
    assert sorted(p.alloc(4)) == [1, 2, 3, 4]


# ----------------------------------------------------------------------
# Sim-device helpers
# ----------------------------------------------------------------------

def test_sim_mesh_device_order_and_tier_slices():
    """make_sim_mesh takes the FIRST n devices in jax.devices() order so
    tier placement can carve disjoint slices; make_tier_mesh builds a
    model=1 mesh over an explicit slice and rejects empty ones."""
    devs = jax.devices()
    assert len(devs) >= 8          # conftest ran ensure_sim_devices(8)
    mesh = make_sim_mesh(4)
    assert dict(mesh.shape) == {"data": 4, "model": 1}
    assert list(mesh.devices.ravel()) == devs[:4]
    tier = make_tier_mesh(devs[4:6])
    assert dict(tier.shape) == {"data": 2, "model": 1}
    assert list(tier.devices.ravel()) == devs[4:6]
    with pytest.raises(ValueError, match="empty"):
        make_tier_mesh([])


def test_ensure_sim_devices_raises_after_backend_lock(monkeypatch):
    """The backend is locked at 8 by conftest: asking for more must be
    a loud RuntimeError, not a silent single-device run."""
    import os
    monkeypatch.setenv("XLA_FLAGS", os.environ.get("XLA_FLAGS", ""))
    ensure_sim_devices(8)          # satisfied: no-op
    with pytest.raises(RuntimeError, match="already"):
        ensure_sim_devices(64)


# ----------------------------------------------------------------------
# Scheduler(mesh=...): validation + device pinning
# ----------------------------------------------------------------------

def _tiny_cfg():
    return ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                       d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
                       d_ff=128, vocab_size=96, remat=False, source="test")


def _gcfg():
    return GenConfig(max_new_tokens=6, temperature=0.7, top_p=1.0, eos_id=2)


def test_scheduler_mesh_validation():
    cfg = _tiny_cfg()
    no_data = jax.sharding.Mesh(np.asarray(jax.devices()[:2]), ("model",))
    with pytest.raises(ValueError, match="data"):
        Scheduler(None, cfg, None, _gcfg(), n_lanes=4, mesh=no_data)
    with pytest.raises(ValueError, match="model"):
        Scheduler(None, cfg, None, _gcfg(), n_lanes=4,
                  mesh=make_sim_mesh(2, 2))
    with pytest.raises(ValueError, match="divide"):
        Scheduler(None, cfg, None, _gcfg(), n_lanes=6, mesh=make_sim_mesh(4))
    with pytest.raises(ValueError, match="lanes per shard"):
        Scheduler(None, cfg, None, _gcfg(), n_lanes=4, mesh=make_sim_mesh(4))


def test_one_device_mesh_pins_execution():
    """A 1-device mesh is shard count 1 with the semantics of PLACEMENT:
    the loop's cache lives on exactly that device and completions still
    match the (device-0) single-device run — the primitive cascade tier
    placement is built from."""
    from repro.models import model as M
    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    target = jax.devices()[3]
    reqs = [Request(uid=u, tokens=[5 + u] * (3 + 5 * u), max_new_tokens=6)
            for u in range(3)]

    def run(mesh):
        sched = Scheduler(params, cfg, None, _gcfg(), n_lanes=2,
                          paged=True, block_size=8, max_prompt_len=32,
                          mesh=mesh)
        loop = sched.loop(jax.random.PRNGKey(7))
        loop.submit(reqs)
        comps = {c.uid: c.tokens.tolist() for c in loop.drain()}
        devs = {d for leaf in jax.tree.leaves(loop.cache)
                for d in leaf.devices()}
        loop.close()
        return comps, devs

    pinned, devs = run(make_tier_mesh([target]))
    assert devs == {target}, "cache must live on the placed device"
    baseline, _ = run(None)
    assert pinned == baseline


# ----------------------------------------------------------------------
# Model-axis TP: plain GSPMD, greedy tokens equal
# ----------------------------------------------------------------------

def test_model_axis_tp_gspmd_tokens_equal():
    """device_put the params to their (2, 2)-mesh specs and run the
    UNMODIFIED engine under GSPMD: greedy completions equal the
    single-device run.  (Model-axis matmul reductions reorder floats —
    allclose logits, not bit-equal — which is exactly why the serving
    loop's bit-exact sharded mode keeps model=1 and TP composes outside
    it via GSPMD.)"""
    from repro.models import model as M
    from repro.serving.engine import generate
    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    rows = rng.randint(3, 90, (4, 16)).astype(np.int32)
    lens = np.full((4,), 16, np.int32)
    gcfg = GenConfig(max_new_tokens=8, temperature=0.0, top_p=1.0, eos_id=2)
    ref, _ = generate(params, cfg, rows, lens, jax.random.PRNGKey(1), gcfg)
    mesh = make_sim_mesh(2, 2)
    specs = sh.param_specs(cfg, params, mesh)
    sharded = jax.device_put(params, sh.named(mesh, specs))
    with mesh:
        got, _ = generate(sharded, cfg, rows, lens, jax.random.PRNGKey(1),
                          gcfg)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


# ----------------------------------------------------------------------
# Launcher surfacing: the async front-end reports mesh + shard layout
# ----------------------------------------------------------------------

def test_async_server_surfaces_mesh_and_shards():
    """AsyncServer.describe() names the mesh and lanes/shard, and
    close() returns the final summary carrying the same banner — the
    launcher-side contract for 'a serve log records where it ran'."""
    import asyncio

    from repro.launch.async_serve import TTFT, AsyncServer
    from repro.models import model as M

    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sched = Scheduler(params, cfg, None, _gcfg(), n_lanes=8, paged=True,
                      block_size=8, max_prompt_len=32,
                      mesh=make_sim_mesh(4))

    async def serve():
        server = AsyncServer(sched, jax.random.PRNGKey(9))
        banner = server.describe()
        streams = {u: server.submit(u, [5 + u] * 4, tenant=TTFT)
                   for u in range(3)}
        toks = {}
        for u, s in streams.items():
            toks[u] = [t async for t in s]
        summary = await server.close()
        return banner, toks, summary

    banner, toks, summary = asyncio.run(serve())
    assert "data=4" in banner and "2 lanes/shard" in banner
    assert summary["devices"] == banner
    assert summary["served"] == 3 and summary["rounds"] > 0
    assert summary["stats"].leak_report is None
    assert all(len(v) == 6 for v in toks.values())
