"""Per-kernel correctness: Pallas (interpret=True on CPU) vs pure-jnp
oracle, sweeping shapes and dtypes.  (Deliverable c.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.ops import moe_gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_attention_ref
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d,window", [
    (1, 4, 4, 256, 64, 0),       # MHA full causal
    (2, 4, 2, 256, 64, 0),       # GQA
    (1, 4, 1, 384, 64, 0),       # MQA, non-block-multiple S
    (1, 2, 2, 256, 64, 96),      # sliding window
])
def test_flash_attention_vs_ref(b, h, kv, s, d, window, dtype):
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = (jax.random.normal(kq, (b, s, h, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (b, s, kv, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(kv_, (b, s, kv, d)) * 0.5).astype(dtype)
    out = flash_attention(q, k, v, window=window, block_q=128, block_k=128,
                          interpret=True)
    qt, kt, vt = (jnp.swapaxes(x, 1, 2) for x in (q, k, v))
    ref = jnp.swapaxes(attention_ref(qt, kt, vt, window=window), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 128, 2, 64))
    k = jax.random.normal(key, (1, 128, 2, 64))
    v = jax.random.normal(key, (1, 128, 2, 64))
    out = flash_attention(q, k, v, softcap=20.0, interpret=True)
    qt, kt, vt = (jnp.swapaxes(x, 1, 2) for x in (q, k, v))
    ref = jnp.swapaxes(attention_ref(qt, kt, vt, softcap=20.0), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ----------------------------------------------------------------------
# decode attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d,window", [
    (2, 4, 2, 1024, 64, 0),
    (2, 4, 1, 1000, 64, 0),      # ragged S
    (1, 2, 2, 2048, 128, 512),   # window
])
def test_decode_attention_vs_ref(b, h, kv, s, d, window, dtype):
    key = jax.random.PRNGKey(2)
    kq, kk, kv_, kl = jax.random.split(key, 4)
    q = (jax.random.normal(kq, (b, 1, h, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (b, s, kv, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(kv_, (b, s, kv, d)) * 0.5).astype(dtype)
    lengths = jax.random.randint(kl, (b,), s // 2, s + 1)
    out = decode_attention(q, k, v, lengths, window=window, block_k=256,
                           interpret=True)
    qt, kt, vt = (jnp.swapaxes(x, 1, 2) for x in (q, k, v))
    ref = jnp.swapaxes(decode_attention_ref(qt, kt, vt, lengths,
                                            window=window), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ----------------------------------------------------------------------
# paged decode attention
# ----------------------------------------------------------------------

def _paged_case(key, b, h, kv, d, bs, m, dtype):
    """Random pages + a random non-contiguous block table per lane."""
    kq, kk, kv_, kl, kp = jax.random.split(key, 5)
    pages = 1 + b * m                    # page 0 is the trash block
    q = (jax.random.normal(kq, (b, 1, h, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (pages, bs, kv, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(kv_, (pages, bs, kv, d)) * 0.5).astype(dtype)
    ids = jax.random.permutation(kp, jnp.arange(1, pages))[: b * m]
    bt = ids.reshape(b, m).astype(jnp.int32)
    lengths = jax.random.randint(kl, (b,), 1, m * bs + 1)
    return q, k, v, bt, lengths


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,d,bs,m,window", [
    (2, 4, 2, 64, 16, 8, 0),         # GQA
    (1, 4, 1, 64, 32, 5, 0),         # MQA
    (2, 2, 2, 128, 16, 8, 48),       # sliding window through pages
])
def test_paged_decode_attention_vs_ref(b, h, kv, d, bs, m, window, dtype):
    q, k, v, bt, lengths = _paged_case(jax.random.PRNGKey(6), b, h, kv, d,
                                       bs, m, dtype)
    out = paged_decode_attention(q, k, v, bt, lengths, window=window,
                                 interpret=True)
    qt = jnp.swapaxes(q, 1, 2)
    kt, vt = (jnp.transpose(x, (0, 2, 1, 3)) for x in (k, v))
    ref = jnp.swapaxes(paged_decode_attention_ref(qt, kt, vt, bt, lengths,
                                                  window=window), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def _quantize_pages(k, v):
    """Per-(slot, kv-head) absmax int8 pages + f32 scales, layout
    (P, bs, KV, ...) matching the ops-level entry point."""
    from repro.models.attention import quantize_kv
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    return kq, vq, ks, vs


@pytest.mark.parametrize("b,h,kv,d,bs,m,window", [
    (2, 4, 2, 64, 16, 8, 0),         # GQA
    (1, 4, 1, 64, 32, 5, 0),         # MQA
    (2, 2, 2, 128, 16, 8, 48),       # sliding window through pages
])
def test_paged_decode_attention_quant_vs_ref(b, h, kv, d, bs, m, window):
    """The dequant-fused kernel (int8 pages + scales dequantized inside
    the flash loop) against the quant ref — and both against the fp
    kernel run on the pre-dequantized pages, which must agree exactly:
    dequantize-then-attend and attend-with-fused-dequant read identical
    f32 values."""
    from repro.kernels.paged_attention.ops import (
        paged_decode_attention_quant)
    from repro.kernels.paged_attention.ref import (
        paged_decode_attention_quant_ref)
    from repro.models.attention import dequantize_kv
    q, k, v, bt, lengths = _paged_case(jax.random.PRNGKey(9), b, h, kv, d,
                                       bs, m, jnp.float32)
    kq, vq, ks, vs = _quantize_pages(k, v)
    out = paged_decode_attention_quant(q, kq, vq, ks, vs, bt, lengths,
                                       window=window, interpret=True)
    qt = jnp.swapaxes(q, 1, 2)
    kt, vt = (jnp.transpose(x, (0, 2, 1, 3)) for x in (kq, vq))
    kst, vst = (jnp.transpose(x, (0, 2, 1)) for x in (ks, vs))
    ref = jnp.swapaxes(
        paged_decode_attention_quant_ref(qt, kt, vt, kst, vst, bt, lengths,
                                         window=window), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    kdq = dequantize_kv(kq, ks, jnp.float32)
    vdq = dequantize_kv(vq, vs, jnp.float32)
    fused_free = paged_decode_attention(q, kdq, vdq, bt, lengths,
                                        window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fused_free),
                               rtol=2e-5, atol=2e-5)
    # and the whole quantized path stays close to unquantized attention
    fp = paged_decode_attention(q, k, v, bt, lengths, window=window,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fp),
                               rtol=5e-2, atol=5e-2)


def test_paged_ref_matches_dense_ref_through_block_table():
    """Gathering pages in block-table order must reproduce dense decode
    attention over the equivalent contiguous cache exactly."""
    b, h, kv, d, bs, m = 2, 4, 2, 64, 16, 6
    q, k, v, bt, lengths = _paged_case(jax.random.PRNGKey(8), b, h, kv, d,
                                       bs, m, jnp.float32)
    qt = jnp.swapaxes(q, 1, 2)
    kt, vt = (jnp.transpose(x, (0, 2, 1, 3)) for x in (k, v))
    paged = paged_decode_attention_ref(qt, kt, vt, bt, lengths)
    # materialize each lane's contiguous logical cache, then dense ref
    gk = jnp.transpose(kt[bt], (0, 2, 1, 3, 4)).reshape(b, kv, m * bs, d)
    gv = jnp.transpose(vt[bt], (0, 2, 1, 3, 4)).reshape(b, kv, m * bs, d)
    dense = decode_attention_ref(qt, gk, gv, lengths)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


# ----------------------------------------------------------------------
# SSD scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 2, 32, 16, 32),
    (1, 100, 4, 16, 8, 32),      # ragged S
    (1, 256, 2, 64, 128, 64),    # mamba2-like state width
])
def test_ssd_vs_sequential_ref(b, s, h, p, n, chunk, dtype):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    xbar = (jax.random.normal(ks[0], (b, s, h, p)) * 0.3).astype(dtype)
    # realistic decays: a = dt * A <= 0
    a = (-jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))).astype(jnp.float32)
    bmat = (jax.random.normal(ks[2], (b, s, n)) * 0.3).astype(dtype)
    cmat = (jax.random.normal(ks[3], (b, s, n)) * 0.3).astype(dtype)
    y, state = ssd_scan(xbar, a, bmat, cmat, chunk=chunk, interpret=True)
    y_ref, state_ref = ssd_ref(xbar, a, bmat, cmat)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               rtol=1e-2, atol=1e-2)


def test_ssd_matches_model_ssd():
    """Kernel agrees with the model substrate's chunked implementation."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 4)
    b, s, h, p, n = 1, 64, 2, 16, 8
    xbar = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    y_k, st_k = ssd_scan(xbar, a, bm, cm, chunk=16, interpret=True)
    y_m, st_m = ssd_chunked(xbar, a, bm[:, :, None, :], cm[:, :, None, :],
                            chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), rtol=1e-4,
                               atol=1e-4)
    # model state layout (B,H,P,N) matches kernel
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m), rtol=1e-4,
                               atol=1e-4)


# ----------------------------------------------------------------------
# MoE grouped matmul
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", [
    (4, 128, 256, 128),
    (2, 100, 130, 70),           # ragged everything
    (8, 256, 128, 512),
])
def test_moe_gmm_vs_ref(e, c, d, f, dtype):
    key = jax.random.PRNGKey(5)
    k1, k2 = jax.random.split(key)
    x = (jax.random.normal(k1, (e, c, d)) / np.sqrt(d)).astype(dtype)
    w = (jax.random.normal(k2, (e, d, f)) / np.sqrt(d)).astype(dtype)
    out = moe_gmm(x, w, interpret=True)
    ref = moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
